"""Batched greedy serving with per-arch caches (KV / SSM / RG-LRU).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-130m]
"""
import argparse

import jax

from repro.launch.mesh import make_mesh, set_ambient_mesh

from repro.configs import ARCHS, get_config
from repro.core import nom_allreduce_banks, nom_reduce
from repro.models import make_model
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b",
                    choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--policy", default="spread",
                    choices=("spread", "partition", "stall_feedback"))
    ap.add_argument("--sched-policy", default="arrival",
                    help="fabric packing policy for the per-step batches "
                         "(a registered name, or 'auto' to pick from "
                         "stall history)")
    ap.add_argument("--ring-slots", type=int, default=8,
                    help="ring capacity per KV leaf (token slots); decode "
                         "past it emits overwrite-eviction INITs")
    ap.add_argument("--admission-strategy", default="fifo",
                    help="registered tenant-admission drain order "
                         "(fifo | deadline | priority | hybrid)")
    args = ap.parse_args()

    mesh = make_mesh((1, 1), ("data", "model"))
    set_ambient_mesh(mesh)
    cfg = get_config(args.arch, smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, max_len=64, placement_policy=args.policy,
                 sched_policy=args.sched_policy, ring_slots=args.ring_slots,
                 admission_strategy=args.admission_strategy)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, 6), 0, cfg.vocab)
    if cfg.arch_type == "encdec":
        memory = model.encode(params, jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model)))
        out = eng.generate(params, prompt, args.new_tokens, memory=memory)
    else:
        out = eng.generate(params, prompt, args.new_tokens)
    print(f"arch={cfg.name} (reduced config), batch={args.batch}")
    for row in out.tolist():
        print("  prompt", row[:6], "->", row[6:])

    # Cache movement rides the NoM scheduler: one batched circuit setup
    # per prefill/decode step (the stream runs as a bank-pool tenant);
    # ring overwrites + the teardown scrub show up as INIT-class ops.
    tel = eng.transfer_telemetry()
    print(f"\nNoM cache-transfer telemetry over {tel['steps']} steps:")
    print(f"  circuits {tel['scheduled']}/{tel['requests']} scheduled, "
          f"{tel['batch_avg']:.1f} per batched setup")
    print(f"  concurrency: max {tel['max_inflight']} in flight/window, "
          f"avg {tel['avg_inflight']:.2f}")
    print(f"  stall_cycles={tel['stall_cycles']} "
          f"search_rounds={tel['search_rounds']} "
          f"conflicts={tel['conflicts']}")
    print(f"  tenancy: policy={args.policy} "
          f"peak_tenants={tel['peak_tenants']} repacks={tel['repacks']}")
    print(f"  admission: mode={tel['admission']} "
          f"strategy={tel['admission_strategy']} "
          f"queued={tel['queued_tenants']} shed={tel['shed_tenants']} "
          f"idle_evictions={tel['idle_evictions']} "
          f"wait_p99={tel['admission_wait_p99']:.1f}t")
    print(f"  fabric: sched_policy={tel['sched_policy']} "
          f"(engine fabric session: {eng.fabric.n_flushes} flushes)")
    print(f"  eviction/INIT: {tel['init_requests']}/{tel['requests']} "
          f"requests (ring wraps past {args.ring_slots} slots + teardown)")

    # Compute-class demo on the same session: a gradient-accumulation
    # style fan-in (4 operand banks merge at bank 0's ALU) plus a small
    # bank-level all-reduce — both land in the fabric's reduce telemetry.
    _res, rrep = nom_reduce(eng.fabric, srcs=[1, 2, 3, 4], dst=0,
                            nbytes=256)
    _res2, arep = nom_allreduce_banks(eng.fabric, banks=[0, 5, 10],
                                      nbytes=768)
    ftel = eng.fabric.telemetry()
    print(f"  reduce: {ftel['reduce_requests']} fan-ins "
          f"(demo fan-in {rrep.n_windows} windows; all-reduce over 3 "
          f"banks {arep.n_reduce} scatter fan-ins)")
    print(f"  auto-tuned slot widening: "
          f"nom_extra_slots={ftel['nom_extra_slots']}")


if __name__ == "__main__":
    main()
