"""End-to-end training driver: data pipeline -> sharded train step ->
fault-tolerant loop with checkpoints.

Presets:
  tiny  (default) — ~3M params, 60 steps: finishes in ~a minute on CPU.
  100m            — ~100M-param qwen-style decoder, few hundred steps:
                    the assignment's end-to-end shape (CPU: hours; the
                    production path is the same code under a real mesh).

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--preset 100m]
"""
import argparse
import dataclasses

import jax

from repro.launch.mesh import make_mesh, set_ambient_mesh

from repro.configs import get_config
from repro.configs.base import ArchConfig, LayerKind
from repro.data import DataConfig
from repro.models import count_params, make_model
from repro.optim.adamw import AdamWConfig
from repro.train import LoopConfig, TrainState, make_train_step, train_loop


def preset_cfg(name: str) -> tuple[ArchConfig, int, int, int]:
    if name == "100m":
        cfg = ArchConfig(
            name="repro-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv=12, d_ff=3072, vocab=32_000,
            pattern=(LayerKind("attn"),), tie_embeddings=True,
            max_seq=1024, sub_quadratic=False)
        return cfg, 300, 8, 512       # steps, batch, seq
    cfg = get_config("qwen1.5-4b", smoke=True)
    return cfg, 60, 8, 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    set_ambient_mesh(mesh)

    cfg, steps, batch, seq = preset_cfg(args.preset)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,}")
    state = TrainState.create(params)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    step = jax.jit(make_train_step(model, cfg, opt, cast_bf16_gather=True),
                   donate_argnums=(0,))
    data = DataConfig(vocab=cfg.vocab, batch=batch, seq=seq, seed=0)
    loop = LoopConfig(total_steps=steps, ckpt_every=max(steps // 3, 10),
                      ckpt_dir=args.ckpt, log_every=10)
    state, hist = train_loop(step, state, data, loop)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(first {hist[0]['loss']:.4f}); "
          f"stragglers flagged: {sum(h['straggler'] for h in hist)}")


if __name__ == "__main__":
    main()
